"""AOT pipeline: lowering produces valid HLO text + consistent metadata."""

import os

import pytest

from compile.aot import example_args, to_hlo_text
from compile.model import NetSpec, build_fns

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def lowered_small():
    spec = NetSpec(max_jobs=5)
    fns = build_fns(spec)
    args = example_args(spec, 8)
    return {
        name: to_hlo_text(fn.lower(*args[name])) for name, fn in fns.items()
    }


def test_hlo_text_has_entry(lowered_small):
    for name, text in lowered_small.items():
        assert "ENTRY" in text, name
        assert "HloModule" in text, name


def test_policy_infer_signature(lowered_small):
    spec = NetSpec(max_jobs=5)
    text = lowered_small["policy_infer"]
    assert f"f32[{spec.policy_params}]" in text
    assert f"f32[{spec.state_dim}]" in text
    assert f"f32[{spec.num_actions}]" in text


def test_rl_step_uses_i32_actions(lowered_small):
    assert "s32[8]" in lowered_small["rl_step"]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "meta.txt")),
    reason="run `make artifacts` first",
)
def test_meta_matches_specs():
    kv = {}
    with open(os.path.join(ART, "meta.txt")) as f:
        for line in f:
            line = line.strip()
            if line and "=" in line:
                k, v = line.split("=", 1)
                kv[k] = v
    assert kv["num_types"] == "8"
    assert kv["hidden"] == "256"
    for j in (int(x) for x in kv["js"].split(",")):
        spec = NetSpec(max_jobs=j)
        assert int(kv[f"j{j}.S"]) == spec.state_dim
        assert int(kv[f"j{j}.A"]) == spec.num_actions
        assert int(kv[f"j{j}.P"]) == spec.policy_params
        assert int(kv[f"j{j}.PV"]) == spec.value_params


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "meta.txt")),
    reason="run `make artifacts` first",
)
def test_all_artifacts_exist():
    kv = {}
    with open(os.path.join(ART, "meta.txt")) as f:
        for line in f:
            if "=" in line:
                k, v = line.strip().split("=", 1)
                kv[k] = v
    for j in kv["js"].split(","):
        for name in ("policy_infer", "value_infer", "sl_step", "rl_step"):
            path = os.path.join(ART, f"{name}_j{j}.hlo.txt")
            assert os.path.exists(path), path
            assert os.path.getsize(path) > 1000, path
