"""Pure-jnp oracle for the Pallas kernels — the CORE correctness signal.

Every kernel in :mod:`fused_mlp` has a reference implementation here written
with nothing but ``jax.numpy``.  pytest (and hypothesis sweeps) assert
``assert_allclose(kernel(...), ref(...))`` across shapes and dtypes; the
AOT artifacts additionally embed the kernels so the rust-side integration
tests recheck the same numerics end to end.
"""

import jax.numpy as jnp


def ref_matmul(x, w):
    return jnp.matmul(
        x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)


def ref_fused_linear(x, w, b, activation: str = "relu"):
    z = (
        jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
        + b.astype(jnp.float32)[None, :]
    )
    if activation == "relu":
        z = jnp.maximum(z, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return z.astype(x.dtype)


def ref_mlp(x, params, activations):
    """Chain of ref_fused_linear layers; params = [(W, b), ...]."""
    h = x
    for (w, b), act in zip(params, activations):
        h = ref_fused_linear(h, w, b, act)
    return h
