"""L1 — Pallas kernels for the DL² policy/value networks.

The hot spot of DL² is the policy-network forward/backward executed on every
scheduling inference and every SL/RL update.  We implement it as a fused
``y = act(x @ W + b)`` Pallas kernel plus a plain tiled matmul used by the
custom VJP, so the kernel sits on *both* the inference and the training path
of every AOT artifact.

TPU adaptation (see DESIGN.md §Hardware-Adaptation): the GEMM is tiled over
``(BM, BN)`` output blocks with the full K panel resident in VMEM (K ≤ 520
for every DL² shape, so an x-panel + W-panel + accumulator is ~330 KiB — far
under the 16 MiB VMEM budget), accumulation is f32 for the MXU, and the
bias + ReLU epilogue is fused so the activation never makes a second HBM
round trip.

All kernels run ``interpret=True`` on this image: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that the
rust runtime executes byte-for-byte like any other op.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default output-tile sizes.  128 matches the MXU systolic-array edge; the
# wrapper pads M/N up to multiples so the grid always divides exactly.
BLOCK_M = 128
BLOCK_N = 128

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One (BM, BN) output tile: o = act(x_panel @ w_panel + b)."""
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation != "none":  # pragma: no cover - guarded at trace time
        raise ValueError(f"unknown activation {activation!r}")
    o_ref[...] = acc.astype(o_ref.dtype)


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Plain (BM, BN) matmul tile used by the VJP."""
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def _pallas_fused_linear(x, w, b, activation: str, bm: int, bn: int):
    """Padded pallas_call for y = act(x @ w + b); shapes (M,K)@(K,N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, np_ - n)))
    bp = jnp.pad(b, ((0, np_ - n),))
    out = pl.pallas_call(
        partial(_fused_linear_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=INTERPRET,
    )(xp, wp, bp)
    return out[:m, :n]


def pallas_matmul(x, w, bm: int = BLOCK_M, bn: int = BLOCK_N):
    """Tiled pallas matmul with automatic edge padding; used by the VJP."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, np_ - n)))
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=INTERPRET,
    )(xp, wp)
    return out[:m, :n]


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation: str = "relu"):
    """``act(x @ w + b)`` as one fused Pallas pass.

    Differentiable: the custom VJP routes dx/dW through :func:`pallas_matmul`
    so the kernel is exercised on the backward path of the SL/RL artifacts
    as well.
    """
    return _pallas_fused_linear(x, w, b, activation, BLOCK_M, BLOCK_N)


def _fused_linear_fwd(x, w, b, activation):
    y = _pallas_fused_linear(x, w, b, activation, BLOCK_M, BLOCK_N)
    # For ReLU, (y > 0) is exactly the pre-activation mask, so we avoid
    # stashing z and recompute nothing.
    return y, (x, w, y)


def _fused_linear_bwd(activation, res, dy):
    x, w, y = res
    if activation == "relu":
        dz = dy * (y > 0).astype(dy.dtype)
    else:
        dz = dy
    dx = pallas_matmul(dz, w.T)
    dw = pallas_matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
