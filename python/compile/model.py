"""L2 — the DL² policy/value networks and their SL/RL update steps in JAX.

This module is build-time only: :mod:`compile.aot` lowers the jitted
functions here to HLO text, and the rust coordinator executes those
artifacts through PJRT.  Nothing in here runs on the request path.

Architecture (paper §4.1/§6.2):
  * input state ``s``: the flattened ``J×(L+5)`` matrix
    ``(x one-hot type, d slots-run, e epochs-left, r dominant-res, w, u)``;
  * 2 fully-connected hidden layers of 256 ReLU neurons;
  * policy head: softmax over ``A = 3J+1`` actions
    ((i,0)=+1 worker, (i,1)=+1 PS, (i,2)=+1 worker+1 PS for each job i,
    plus the void action);
  * value head: a single linear neuron (actor-critic critic, §4.3).

Parameters travel as ONE flat f32 vector so the rust runtime marshals a
single literal per network; layer boundaries are recomputed from
``(S, H, out)`` on both sides (see ``artifacts/meta.txt``).

Every dense layer goes through the L1 Pallas kernel
:func:`compile.kernels.fused_mlp.fused_linear` — forward *and* backward
(custom VJP) — so the kernel is on the hot path of every artifact.
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from .kernels.fused_mlp import fused_linear

# ---------------------------------------------------------------------------
# Network specification
# ---------------------------------------------------------------------------

NUM_JOB_TYPES = 8  # L — Table 1 has 8 model categories.
HIDDEN = 256  # paper §6.2: 2 hidden layers with 256 neurons each.
FEATURES_PER_JOB = NUM_JOB_TYPES + 5  # one-hot type + (d, e, r, w, u)


@dataclass(frozen=True)
class NetSpec:
    """Static shape information for one (J,)-parameterized artifact set."""

    max_jobs: int  # J
    num_types: int = NUM_JOB_TYPES  # L
    hidden: int = HIDDEN  # H

    @property
    def state_dim(self) -> int:  # S
        return self.max_jobs * (self.num_types + 5)

    @property
    def num_actions(self) -> int:  # A = 3J + 1 (§4.1)
        return 3 * self.max_jobs + 1

    def layer_shapes(self, out_dim: int):
        s, h = self.state_dim, self.hidden
        return [(s, h), (h,), (h, h), (h,), (h, out_dim), (out_dim,)]

    def param_count(self, out_dim: int) -> int:
        total = 0
        for shape in self.layer_shapes(out_dim):
            n = 1
            for d in shape:
                n *= d
            total += n
        return total

    @property
    def policy_params(self) -> int:  # P
        return self.param_count(self.num_actions)

    @property
    def value_params(self) -> int:  # Pv
        return self.param_count(1)


def unflatten(theta, spec: NetSpec, out_dim: int):
    """Flat f32 vector -> [(W1,b1),(W2,b2),(W3,b3)]."""
    params, off = [], 0
    shapes = spec.layer_shapes(out_dim)
    for wi in range(0, len(shapes), 2):
        wshape, bshape = shapes[wi], shapes[wi + 1]
        wn = wshape[0] * wshape[1]
        w = theta[off : off + wn].reshape(wshape)
        off += wn
        b = theta[off : off + bshape[0]]
        off += bshape[0]
        params.append((w, b))
    return params


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def mlp_forward(theta, states, spec: NetSpec, out_dim: int):
    """states: [B, S] -> [B, out_dim] raw outputs (no head activation)."""
    (w1, b1), (w2, b2), (w3, b3) = unflatten(theta, spec, out_dim)
    h = fused_linear(states, w1, b1, "relu")
    h = fused_linear(h, w2, b2, "relu")
    return fused_linear(h, w3, b3, "none")


def policy_logits(theta, states, spec: NetSpec):
    return mlp_forward(theta, states, spec, spec.num_actions)


def value_forward(theta_v, states, spec: NetSpec):
    """[B, S] -> [B] state values (final layer is a single linear neuron)."""
    return mlp_forward(theta_v, states, spec, 1)[:, 0]


def policy_infer(theta, state, spec: NetSpec):
    """Single-state inference: [S] -> action probabilities [A]."""
    logits = policy_logits(theta, state[None, :], spec)[0]
    return jax.nn.softmax(logits)


def policy_infer_batch(theta, states, spec: NetSpec):
    """True batched inference: [B, S] -> action probabilities [B, A].

    Row ``k`` is exactly ``policy_infer(theta, states[k])``: the forward
    pass and the softmax are row-independent, which is what lets the
    rust engine zero-pad a lockstep round up to the bucket width and
    truncate the padding rows from the result without perturbing the
    real ones.  Lowered once per bucket width B as
    ``policy_infer_b{B}_j{J}.hlo.txt``.
    """
    return jax.nn.softmax(policy_logits(theta, states, spec), axis=-1)


def value_infer(theta_v, state, spec: NetSpec):
    """Single-state critic evaluation: [S] -> [1]."""
    return value_forward(theta_v, state[None, :], spec)


# ---------------------------------------------------------------------------
# Adam (carried by the caller as flat (m, v, t) so each HLO step is pure)
# ---------------------------------------------------------------------------

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def adam_update(theta, m, v, t, grad, lr):
    """One Adam step on a flat parameter vector; returns (theta', m', v', t')."""
    t = t + 1.0
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * grad
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * grad * grad
    mhat = m / (1.0 - ADAM_B1**t)
    vhat = v / (1.0 - ADAM_B2**t)
    theta = theta - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return theta, m, v, t


# ---------------------------------------------------------------------------
# Offline supervised learning step (§4.2)
# ---------------------------------------------------------------------------


def sl_loss(theta, states, labels, spec: NetSpec):
    """Cross-entropy of NN decisions vs the incumbent scheduler's decisions."""
    logits = policy_logits(theta, states, spec)
    logp = jax.nn.log_softmax(logits)
    picked = jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return -jnp.mean(picked)


def sl_step(theta, m, v, t, states, labels, lr, *, spec: NetSpec):
    """(θ, adam, batch, lr) -> (θ', adam', loss).  SGD on cross-entropy."""
    loss, grad = jax.value_and_grad(sl_loss)(theta, states, labels, spec)
    theta, m, v, t = adam_update(theta, m, v, t, grad, lr)
    return theta, m, v, t, loss


# ---------------------------------------------------------------------------
# Online RL step: actor-critic REINFORCE with entropy regularization (§4.3)
# ---------------------------------------------------------------------------


def _normalize_adv(advantages):
    """Batch z-scoring of advantages.

    Raw discounted returns are O(1..20) while the freshly-initialized
    critic predicts ~0, so un-normalized advantages uniformly inflate
    every sampled action's log-probability and collapse the softmax within
    a few updates.  Normalizing to zero mean / unit variance keeps the
    REINFORCE gradient scale stable across training stages (standard
    practice; scale-invariant in the bandit sense, so Eqn 2's direction is
    preserved).
    """
    mu = jnp.mean(advantages)
    sd = jnp.std(advantages) + 1e-6
    return (advantages - mu) / sd


def _policy_loss(theta, states, actions, advantages, beta, spec: NetSpec):
    logits = policy_logits(theta, states, spec)
    logp = jax.nn.log_softmax(logits)
    p = jax.nn.softmax(logits)
    picked = jnp.take_along_axis(logp, actions[:, None], axis=1)[:, 0]
    adv = _normalize_adv(advantages)
    pg = -jnp.mean(picked * adv)  # REINFORCE with advantage (Eqn 2)
    entropy = -jnp.mean(jnp.sum(p * logp, axis=1))
    return pg - beta * entropy, entropy


def _value_loss(theta_v, states, returns, spec: NetSpec):
    v = value_forward(theta_v, states, spec)
    return jnp.mean((v - returns) ** 2)


def pg_step(
    theta,
    m,
    v,
    t,
    states,
    actions,
    advantages,
    lr,
    beta,
    *,
    spec: NetSpec,
):
    """Plain REINFORCE step with caller-provided advantages (no critic).

    Used by the Table-2 "without actor-critic" ablation, where the rust
    driver substitutes an exponential-moving-average reward baseline for
    the value network.  Returns ``(θ', m', v', t', loss, entropy)``.
    """
    (loss, entropy), grad = jax.value_and_grad(_policy_loss, has_aux=True)(
        theta, states, actions, advantages, beta, spec
    )
    theta, m, v, t = adam_update(theta, m, v, t, grad, lr)
    return theta, m, v, t, loss, entropy


def rl_step(
    theta,
    m,
    v,
    t,
    theta_v,
    mv,
    vv,
    tv,
    states,
    actions,
    returns,
    lr_p,
    lr_v,
    beta,
    *,
    spec: NetSpec,
):
    """One actor-critic update on a replay mini-batch.

    ``returns`` are the empirical discounted cumulative rewards G_t computed
    by the rust coordinator.  The critic supplies the baseline:
    advantage = G − V(s) (stop-gradient), the actor maximizes
    ``logπ(a|s)·adv + β·H(π)``, and the critic regresses V(s) → G
    (temporal-difference target, §4.3).

    Returns ``(θ', m', v', t', θv', mv', vv', tv', ploss, vloss, entropy)``.
    """
    baseline = value_forward(theta_v, states, spec)
    advantages = returns - jax.lax.stop_gradient(baseline)

    (ploss, entropy), pgrad = jax.value_and_grad(_policy_loss, has_aux=True)(
        theta, states, actions, advantages, beta, spec
    )
    vloss, vgrad = jax.value_and_grad(_value_loss)(
        theta_v, states, returns, spec
    )

    theta, m, v, t = adam_update(theta, m, v, t, pgrad, lr_p)
    theta_v, mv, vv, tv = adam_update(theta_v, mv, vv, tv, vgrad, lr_v)
    return theta, m, v, t, theta_v, mv, vv, tv, ploss, vloss, entropy


# ---------------------------------------------------------------------------
# jit wrappers (what aot.py lowers)
# ---------------------------------------------------------------------------


def build_fns(spec: NetSpec):
    """Return the dict of jittable fns lowered into artifacts for this J."""
    return {
        "policy_infer": jax.jit(partial(policy_infer, spec=spec)),
        "value_infer": jax.jit(partial(value_infer, spec=spec)),
        "sl_step": jax.jit(partial(sl_step, spec=spec)),
        "rl_step": jax.jit(partial(rl_step, spec=spec)),
        "pg_step": jax.jit(partial(pg_step, spec=spec)),
    }
