"""AOT pipeline: lower the L2 jitted functions to HLO text artifacts.

Run once at ``make artifacts``::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits, for every J in ``--js`` (default 5,10,20,40):

    artifacts/policy_infer_j{J}.hlo.txt
    artifacts/policy_infer_b{B}_j{J}.hlo.txt   (one per bucket width B)
    artifacts/value_infer_j{J}.hlo.txt
    artifacts/sl_step_j{J}.hlo.txt
    artifacts/rl_step_j{J}.hlo.txt

plus ``artifacts/meta.txt`` (flat key=value, parsed by rust) and
``artifacts/meta.json`` (for humans).  The bucketed ``[B, S] -> [B, A]``
inference artifacts back the rust engine's batched fast path: a lockstep
round of N states is chunked over the bucket widths (powers of two,
ascending), each chunk zero-padded to its bucket and truncated after
execution — the ``buckets=`` meta line tells the engine which widths
exist.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    ADAM_B1,
    ADAM_B2,
    ADAM_EPS,
    HIDDEN,
    NUM_JOB_TYPES,
    NetSpec,
    build_fns,
    policy_infer_batch,
)

DEFAULT_JS = (5, 10, 20, 40)
DEFAULT_BATCH = 256  # paper §6.2: mini-batch of 256 samples
# Inference bucket widths: strictly ascending powers of two.  A lockstep
# round is covered by full chunks of the largest bucket plus the
# smallest bucket that fits the tail (rust `bucket_plan`).
DEFAULT_BUCKETS = (2, 4, 8, 16, 32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def example_args(spec: NetSpec, batch: int):
    """ShapeDtypeStructs matching each artifact's signature."""
    s, a = spec.state_dim, spec.num_actions  # noqa: F841 (a: doc aid)
    p, pv = spec.policy_params, spec.value_params
    scalar = f32()
    return {
        "policy_infer": (f32(p), f32(s)),
        "value_infer": (f32(pv), f32(s)),
        "sl_step": (
            f32(p), f32(p), f32(p), scalar,  # θ, m, v, t
            f32(batch, s), i32(batch), scalar,  # states, labels, lr
        ),
        "rl_step": (
            f32(p), f32(p), f32(p), scalar,  # θ, m, v, t
            f32(pv), f32(pv), f32(pv), scalar,  # θv, mv, vv, tv
            f32(batch, s), i32(batch), f32(batch),  # states, actions, G
            scalar, scalar, scalar,  # lr_p, lr_v, β
        ),
        "pg_step": (
            f32(p), f32(p), f32(p), scalar,  # θ, m, v, t
            f32(batch, s), i32(batch), f32(batch),  # states, actions, adv
            scalar, scalar,  # lr, β
        ),
    }


def emit(spec: NetSpec, batch: int, out_dir: str, buckets=(), verbose: bool = True):
    fns = build_fns(spec)
    args = example_args(spec, batch)
    written = {}

    def write(name, lowered):
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}_j{spec.max_jobs}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written[name] = (path, len(text))
        if verbose:
            print(f"  {path}: {len(text)} chars")

    for name, fn in fns.items():
        write(name, fn.lower(*args[name]))
    # Bucketed [B, S] -> [B, A] inference: one artifact per width.
    batched = jax.jit(lambda theta, states: policy_infer_batch(theta, states, spec))
    for b in buckets:
        lowered = batched.lower(f32(spec.policy_params), f32(b, spec.state_dim))
        write(f"policy_infer_b{b}", lowered)
    return written


def write_meta(js, batch, out_dir, buckets=()):
    lines = [
        f"num_types={NUM_JOB_TYPES}",
        f"hidden={HIDDEN}",
        f"batch={batch}",
        f"adam_b1={ADAM_B1}",
        f"adam_b2={ADAM_B2}",
        f"adam_eps={ADAM_EPS}",
        "js=" + ",".join(str(j) for j in js),
    ]
    if buckets:
        lines.append("buckets=" + ",".join(str(b) for b in buckets))
    meta_json = {
        "num_types": NUM_JOB_TYPES,
        "hidden": HIDDEN,
        "batch": batch,
        "adam": {"b1": ADAM_B1, "b2": ADAM_B2, "eps": ADAM_EPS},
        "js": list(js),
        "buckets": list(buckets),
        "specs": {},
    }
    for j in js:
        spec = NetSpec(max_jobs=j)
        kv = {
            "S": spec.state_dim,
            "A": spec.num_actions,
            "P": spec.policy_params,
            "PV": spec.value_params,
        }
        for k, v in kv.items():
            lines.append(f"j{j}.{k}={v}")
        meta_json["specs"][str(j)] = kv
    with open(os.path.join(out_dir, "meta.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta_json, f, indent=2)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--js", default=",".join(str(j) for j in DEFAULT_JS),
        help="comma-separated J values to emit artifacts for",
    )
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument(
        "--buckets", default=",".join(str(b) for b in DEFAULT_BUCKETS),
        help="comma-separated [B, S] inference bucket widths (ascending "
        "powers of two; empty disables the bucketed artifacts)",
    )
    args = ap.parse_args()

    js = tuple(int(x) for x in args.js.split(","))
    buckets = tuple(int(x) for x in args.buckets.split(",") if x.strip())
    assert all(b > 0 and b & (b - 1) == 0 for b in buckets), (
        f"bucket widths must be powers of two: {buckets}"
    )
    assert all(a < b for a, b in zip(buckets, buckets[1:])), (
        f"bucket widths must be strictly ascending: {buckets}"
    )
    os.makedirs(args.out_dir, exist_ok=True)
    for j in js:
        spec = NetSpec(max_jobs=j)
        print(
            f"J={j}: S={spec.state_dim} A={spec.num_actions} "
            f"P={spec.policy_params} Pv={spec.value_params}"
        )
        emit(spec, args.batch, args.out_dir, buckets)
    write_meta(js, args.batch, args.out_dir, buckets)
    print(f"meta written to {args.out_dir}/meta.txt")


if __name__ == "__main__":
    main()
