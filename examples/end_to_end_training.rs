//! End-to-end driver (EXPERIMENTS.md §End-to-end): the full DL² system on
//! a real workload — offline supervised warm-up from DRF, then online
//! actor-critic RL in the contended-cluster environment, logging the
//! validation JCT curve and comparing the final policy against every
//! baseline scheduler.
//!
//! This exercises all three layers on the hot path: L3 rust coordinator
//! (scheduling loop, env, replay) → L2 JAX model (SL/RL update artifacts)
//! → L1 Pallas fused-linear kernels (inside every artifact), through PJRT.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end_training
//! # faster smoke run:
//! DL2_BENCH_SCALE=0.2 cargo run --release --example end_to_end_training
//! # serial reference path (same episode seeds, for wall-clock A/B):
//! cargo run --release --example end_to_end_training -- --serial
//! ```

use std::time::Instant;

use dl2::pipeline::{
    baseline_by_name, baseline_jct, run_pipeline, validation_trace, PipelineConfig,
};
use dl2::runtime::load_default_engine;
use dl2::util::{scaled, Args, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let engine = load_default_engine()?;
    let cfg = PipelineConfig {
        sl_steps: scaled(250, 30),
        rl_rounds: scaled(8, 2),
        rl_round_episodes: 4,
        parallel: !args.bool_or("serial", false),
        ..Default::default()
    };
    println!(
        "end-to-end: {} servers, {} jobs/trace, J={}, SL {} steps, RL {} rounds x {} episodes ({})",
        cfg.cluster.num_servers,
        cfg.trace.num_jobs,
        cfg.dl2.j,
        cfg.sl_steps,
        cfg.rl_rounds,
        cfg.rl_round_episodes,
        if cfg.parallel { "parallel" } else { "serial" }
    );

    let t0 = Instant::now();
    let result = run_pipeline(&cfg, engine)?;
    let train_time = t0.elapsed();

    // The training curve (Fig 10-style): validation JCT vs NN updates.
    let mut curve = Table::new(
        "DL2 training curve (validation avg JCT vs NN updates)",
        &["updates", "avg_jct_slots"],
    );
    for (u, j) in &result.history {
        curve.row(vec![u.to_string(), format!("{j:.3}")]);
    }
    curve.emit("end_to_end_curve");

    // Final comparison against all baselines on the same validation trace.
    let val = validation_trace(&cfg.trace);
    let mut cmp = Table::new(
        "final comparison (validation avg JCT, slots)",
        &["scheduler", "avg_jct", "vs_drf_%"],
    );
    let mut drf_ref = None;
    for name in ["drf", "tetris", "optimus", "fifo", "srtf"] {
        let mut mk = || baseline_by_name(name).unwrap();
        let jct = baseline_jct(&mut mk, &cfg.cluster, &val, 3, cfg.rl_opts.max_slots);
        if name == "drf" {
            drf_ref = Some(jct);
        }
        let vs = drf_ref.map(|d| 100.0 * (d - jct) / d).unwrap_or(0.0);
        cmp.row(vec![name.into(), format!("{jct:.3}"), format!("{vs:+.1}")]);
    }
    let drf = drf_ref.unwrap();
    let dl2_jct = result.final_jct;
    cmp.row(vec![
        "dl2 (SL only)".into(),
        format!("{:.3}", result.sl_jct),
        format!("{:+.1}", 100.0 * (drf - result.sl_jct) / drf),
    ]);
    cmp.row(vec![
        "dl2 (SL+RL)".into(),
        format!("{dl2_jct:.3}"),
        format!("{:+.1}", 100.0 * (drf - dl2_jct) / drf),
    ]);
    cmp.emit("end_to_end_comparison");

    println!(
        "trained {} NN updates in {:.1?} ({:.0} ms/update incl. env)",
        result.trainer.updates,
        train_time,
        train_time.as_millis() as f64 / result.trainer.updates.max(1) as f64
    );
    println!(
        "headline: DL2 {:+.1}% vs DRF (paper: +44.1% at full scale/training budget)",
        100.0 * (drf - dl2_jct) / drf
    );
    Ok(())
}
