//! Quickstart: load the AOT artifacts, warm the DL² policy up on DRF
//! traces (supervised learning, §4.2), and compare it against the DRF
//! incumbent on a held-out validation trace.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use dl2::cluster::Cluster;
use dl2::pipeline::{experiment_cluster, experiment_trace, validation_trace};
use dl2::rl::{evaluate_policy, generate_dataset, train_sl};
use dl2::runtime::load_default_engine;
use dl2::scheduler::{run_episode, Dl2Config, Dl2Scheduler, Drf};
use dl2::trace::{generate, TraceConfig};
use dl2::util::Rng;

fn main() -> anyhow::Result<()> {
    // 1. The runtime: rust loads the HLO artifacts produced once by
    //    `make artifacts` — Python is not involved from here on.
    let engine = load_default_engine()?;
    println!(
        "loaded artifacts: L={} hidden={} J variants {:?}",
        engine.meta.num_types, engine.meta.hidden, engine.meta.js
    );

    let cluster_cfg = experiment_cluster();
    let trace_cfg = experiment_trace();
    let val = validation_trace(&trace_cfg);

    // 2. The incumbent: DRF on the validation trace.
    let drf_res = run_episode(
        Cluster::new(cluster_cfg.clone()),
        &val,
        &mut Drf,
        0.0,
        3000,
    );
    println!(
        "DRF  : avg JCT {:.2} slots (makespan {})",
        drf_res.avg_jct_slots, drf_res.makespan_slots
    );

    // 3. Supervised warm-up: imitate DRF for a few hundred updates.
    let dl2_cfg = Dl2Config {
        j: 10,
        ..Default::default()
    };
    let mut sched = Dl2Scheduler::new(engine, dl2_cfg);
    let traces: Vec<_> = (0..3)
        .map(|i| {
            generate(&TraceConfig {
                seed: 100 + i,
                ..trace_cfg.clone()
            })
        })
        .collect();
    let dataset = generate_dataset(&mut Drf, &cluster_cfg, &traces, 10, &sched.schema, 3000);
    println!("SL dataset: {} labeled decisions", dataset.len());
    let mut rng = Rng::new(0);
    let losses = train_sl(&mut sched, &dataset, 150, &mut rng);
    println!(
        "SL   : cross-entropy {:.3} -> {:.3} over {} updates",
        losses[0],
        losses.last().unwrap(),
        losses.len()
    );

    // 4. Evaluate the warmed-up policy.
    let jct = evaluate_policy(&mut sched, &cluster_cfg, &val, 3000);
    println!("DL2  : avg JCT {jct:.2} slots after SL only");
    println!("(run `cargo run --release --example end_to_end_training` for the full SL+RL pipeline)");
    Ok(())
}
