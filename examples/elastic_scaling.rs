//! Elastic-scaling demo (§5): hot PS/worker scaling on a live PS-training
//! job with real parameter buffers, versus the checkpoint-restart
//! baseline — the Fig 7 walkthrough as runnable code.
//!
//! ```bash
//! cargo run --release --example elastic_scaling
//! ```

use dl2::cluster::catalog;
use dl2::elastic::{checkpoint::measure_checkpoint_scaling, ElasticConfig, ElasticJob};
use dl2::util::Table;

fn main() -> anyhow::Result<()> {
    let cfg = ElasticConfig::default();

    // Hot scaling across three Table-1 models of very different sizes.
    let mut t = Table::new(
        "hot scaling: add one PS (ms per protocol step)",
        &["model", "size_mb", "register", "assign", "migrate", "worker_upd", "suspension"],
    );
    for name in ["ctc", "resnet50", "vgg16"] {
        let jt = catalog().into_iter().find(|j| j.name == name).unwrap();
        let mut job = ElasticJob::start(cfg.clone(), jt.model_mb, 2, 2);
        std::thread::sleep(std::time::Duration::from_millis(40));
        let r = job.add_ps();
        assert!(job.verify_integrity(), "{name}: parameter blocks corrupted");
        t.row(vec![
            name.into(),
            format!("{:.0}", jt.model_mb),
            format!("{:.2}", r.registration_ms),
            format!("{:.2}", r.assignment_ms),
            format!("{:.2}", r.migration_ms),
            format!("{:.2}", r.worker_update_ms),
            format!("{:.2}", r.avg_suspension_ms),
        ]);
        job.shutdown();
    }
    t.emit("elastic_hot");

    // Checkpoint-restart baseline on ResNet-50 for contrast (Fig 11).
    let jt = catalog().into_iter().find(|j| j.name == "resnet50").unwrap();
    let report = measure_checkpoint_scaling(&cfg, jt.model_mb, 2, 2, 1)?;
    let mut c = Table::new(
        "checkpoint-restart baseline: add one PS (resnet50)",
        &["component", "ms"],
    );
    c.row(vec!["checkpoint (stop+serialize+write)".into(), format!("{:.1}", report.checkpoint_ms)]);
    c.row(vec!["restore (read+relaunch)".into(), format!("{:.1}", report.restore_ms)]);
    c.row(vec![
        "modeled container restart (documented constant)".into(),
        format!("{:.1}", report.modeled_restart_ms),
    ]);
    c.row(vec![
        "TOTAL suspension".into(),
        format!("{:.1}", report.total_suspension_ms()),
    ]);
    c.emit("elastic_checkpoint");

    println!("hot scaling suspends workers for tens of ms; checkpoint-restart for tens of seconds.");
    Ok(())
}
